"""Fleet routing, workload generation, and latency accounting tests."""

import numpy as np
import pytest

from repro.serving.adapter_cache import AdapterCache, CacheConfig, DMAModel
from repro.serving.engine import EngineConfig, ServingEngine
from repro.serving.request import Request, ServeStats
from repro.serving.router import Fleet, FleetConfig
from repro.serving.scheduler import SchedulerConfig
from repro.serving.workload import (WorkloadSpec, load_trace, make_workload,
                                    save_trace, zipf_pmf)


class FixedCostExecutor:
    """Hand-computable executor: prefill 1s, decode step 0.5s."""

    def __init__(self, prefill=1.0, decode=0.5):
        self._prefill, self._decode = prefill, decode

    def adapter_bytes(self, aid):
        return 1

    def shared_bytes(self):
        return 0

    def decode_step_time(self, batch):
        return self._decode if batch else 0.0

    def prefill_time(self, req):
        return self._prefill


def _engine(max_batch=8, prefetch=False):
    eng = ServingEngine(
        EngineConfig(scheduler=SchedulerConfig(max_batch=max_batch),
                     adapter_budget_bytes=1e9, prefetch=prefetch),
        FixedCostExecutor())
    # zero-cost DMA so latency arithmetic is exact
    eng.cache = AdapterCache(CacheConfig(1e9, DMAModel(bandwidth=1e30,
                                                       latency=0.0)))
    return eng


def _fleet(n, policy, cluster_of=None, max_batch=8, spill=1.0):
    cfg = FleetConfig(n_replicas=n, policy=policy, spill_requests=spill)
    return Fleet(cfg, [_engine(max_batch) for _ in range(n)], cluster_of)


def _reqs(adapters, arrivals=None, new_tokens=2):
    arrivals = arrivals or [0.0] * len(adapters)
    return [Request(rid=i, adapter_id=a, prompt_len=8,
                    max_new_tokens=new_tokens, arrival_time=t)
            for i, (a, t) in enumerate(zip(adapters, arrivals))]


# ---------------------------------------------------------------------------
# TTFT / TPOT / percentile accounting
# ---------------------------------------------------------------------------


def test_ttft_tpot_hand_computed():
    """3 batched requests at t=0; prefill 1s each (sequential), decode 0.5s.

    Admission prefills r0,r1,r2 back-to-back -> clock 3.0; every decode
    step advances all running slots.  First token lands at 3.5 for all.
    """
    eng = _engine()
    reqs = [Request(rid=i, adapter_id=0, prompt_len=8, max_new_tokens=n)
            for i, n in enumerate([1, 2, 3])]
    eng.submit(reqs)
    stats = eng.run()
    assert [r.first_token_time for r in reqs] == [3.5, 3.5, 3.5]
    assert [r.finish_time for r in reqs] == [3.5, 4.0, 4.5]
    assert [r.ttft for r in reqs] == [3.5, 3.5, 3.5]
    assert [r.tpot for r in reqs] == [0.0, 0.5, 0.5]
    assert stats.latencies == [3.5, 4.0, 4.5]
    assert stats.latency_pct(50) == 4.0
    assert stats.ttft_pct(99) == 3.5
    d = stats.to_dict()
    assert d["tpot_p50_s"] == 0.5 and d["latency_p99_s"] == pytest.approx(
        np.percentile([3.5, 4.0, 4.5], 99))


def test_stats_merged_wall_is_max():
    a = ServeStats(n_requests=2, n_tokens=20, wall_time=4.0,
                   latencies=[1.0, 2.0])
    b = ServeStats(n_requests=1, n_tokens=10, wall_time=6.0, latencies=[3.0])
    m = ServeStats.merged([a, b])
    assert m.wall_time == 6.0
    assert m.n_requests == 3 and m.n_tokens == 30
    assert sorted(m.latencies) == [1.0, 2.0, 3.0]


# ---------------------------------------------------------------------------
# routing policies
# ---------------------------------------------------------------------------


def test_round_robin_deterministic():
    reqs = _reqs([5, 1, 7, 3, 5, 1])
    f1 = _fleet(3, "round_robin")
    f1.submit(_reqs([5, 1, 7, 3, 5, 1]))
    f2 = _fleet(3, "round_robin")
    f2.submit(reqs)
    assert f1.assignments == f2.assignments
    assert [f1.assignments[i] for i in range(6)] == [0, 1, 2, 0, 1, 2]


def test_least_outstanding_avoids_busy_replica():
    f = _fleet(2, "least_outstanding", max_batch=1)
    # 2 long requests at t=0 fill both replicas; a later request should go
    # to whichever replica has drained more of its queue
    early = _reqs([0, 1, 2], arrivals=[0.0, 0.0, 0.0], new_tokens=8)
    late = _reqs([3], arrivals=[100.0])
    late[0].rid = 99
    f.submit(early + late)
    counts = [0, 0]
    for rid, rep in f.assignments.items():
        counts[rep] += 1
    assert counts[0] + counts[1] == 4
    # by t=100 everything has drained: the late request sees equal
    # outstanding (0) and goes to replica 0 by the deterministic tiebreak
    assert f.assignments[99] == 0


def test_adapter_affinity_sticky():
    f = _fleet(2, "adapter_affinity")
    f.submit(_reqs([4, 9, 4, 9, 4, 9]))
    reps4 = {f.assignments[i] for i in (0, 2, 4)}
    reps9 = {f.assignments[i] for i in (1, 3, 5)}
    assert len(reps4) == 1 and len(reps9) == 1
    assert reps4 != reps9          # spread over distinct replicas


def test_cluster_affinity_colocates_cluster():
    cluster_of = {a: a % 2 for a in range(8)}   # two clusters
    f = _fleet(4, "cluster_affinity", cluster_of, spill=100.0)
    reqs = _reqs(list(range(8)) * 2)
    f.submit(reqs)
    by_cluster = {}
    for aid, replicas in f.replicas_of_adapter(reqs).items():
        by_cluster.setdefault(cluster_of[aid], set()).update(replicas)
    # every adapter of a cluster lands on that cluster's single home replica
    assert all(len(v) == 1 for v in by_cluster.values()), by_cluster
    assert by_cluster[0] != by_cluster[1]


def test_fleet_single_replica_matches_plain_engine():
    """A 1-replica fleet is exactly the old single-engine study."""
    eng = _engine()
    reqs = _reqs([0, 1, 2, 0], new_tokens=3)
    eng.submit(reqs)
    solo = eng.run()
    f = _fleet(1, "round_robin")
    reqs2 = _reqs([0, 1, 2, 0], new_tokens=3)
    f.submit(reqs2)
    fs = f.run()
    assert fs.total.wall_time == solo.wall_time
    assert fs.total.n_tokens == solo.n_tokens
    assert sorted(fs.total.latencies) == sorted(solo.latencies)


# ---------------------------------------------------------------------------
# workload generation
# ---------------------------------------------------------------------------


def test_zipf_statistics():
    spec = WorkloadSpec(n_requests=20000, n_adapters=64, popularity="zipf",
                        zipf_alpha=1.0, shuffle_ranks=False, seed=3)
    reqs = make_workload(spec)
    counts = np.bincount([r.adapter_id for r in reqs], minlength=64)
    emp = counts / counts.sum()
    pmf = zipf_pmf(64, 1.0)
    # head matches 1/k law within sampling noise; strictly decreasing head
    assert np.allclose(emp[:8], pmf[:8], atol=3e-2)
    assert counts[0] > counts[7] > counts[63]
    # top adapter ~ 1/H(64) ~ 21%
    assert 0.15 < emp[0] < 0.3


def test_uniform_generator_matches_legacy_stream():
    """popularity='uniform' draws the identical stream the seed study used
    (same RNG call order) — the reproducibility special case."""
    spec = WorkloadSpec(n_requests=50, n_adapters=16, seed=0)
    reqs = make_workload(spec)
    rng = np.random.default_rng(0)
    for r in reqs:
        plen = int(np.clip(rng.normal(128, 32), 16, 512))
        aid = int(rng.integers(16))
        assert (r.prompt_len, r.adapter_id) == (plen, aid)
        assert r.arrival_time == 0.0


def test_bursty_arrivals_have_higher_cv():
    pois = make_workload(WorkloadSpec(n_requests=4000, arrival="poisson",
                                      arrival_rate=10.0, seed=1))
    burst = make_workload(WorkloadSpec(n_requests=4000, arrival="gamma",
                                       arrival_rate=10.0, burst_cv=4.0,
                                       seed=1))
    def cv(reqs):
        gaps = np.diff([r.arrival_time for r in reqs])
        return gaps.std() / gaps.mean()
    assert abs(cv(pois) - 1.0) < 0.15
    assert cv(burst) > 2.5
    # same mean rate
    assert burst[-1].arrival_time == pytest.approx(pois[-1].arrival_time,
                                                   rel=0.25)


def test_trace_roundtrip(tmp_path):
    reqs = make_workload(WorkloadSpec(n_requests=20, arrival="poisson",
                                      arrival_rate=5.0, seed=2))
    p = tmp_path / "trace.csv"
    save_trace(str(p), reqs)
    back = load_trace(str(p))
    assert [(r.adapter_id, r.prompt_len, r.max_new_tokens) for r in back] == \
           [(r.adapter_id, r.prompt_len, r.max_new_tokens) for r in reqs]
    assert all(b.arrival_time == pytest.approx(r.arrival_time)
               for b, r in zip(back, reqs))


def test_trace_out_of_order_timestamps_sorted_with_warning(tmp_path):
    """Concurrent-frontend traces arrive unsorted; load_trace must warn,
    sort, and renumber so replay never sees negative inter-arrival gaps."""
    p = tmp_path / "ooo.csv"
    p.write_text("arrival_time,adapter_id,prompt_len,max_new_tokens\n"
                 "2.0,7,16,4\n0.5,3,16,4\n1.0,5,16,4\n")
    with pytest.warns(UserWarning, match="out-of-order"):
        reqs = load_trace(str(p))
    assert [r.arrival_time for r in reqs] == [0.5, 1.0, 2.0]
    assert [r.adapter_id for r in reqs] == [3, 5, 7]
    assert [r.rid for r in reqs] == [0, 1, 2]
    gaps = np.diff([r.arrival_time for r in reqs])
    assert (gaps >= 0).all()


def test_trace_in_order_does_not_warn(tmp_path):
    import warnings as _w
    p = tmp_path / "ok.csv"
    p.write_text("arrival_time,adapter_id,prompt_len,max_new_tokens\n"
                 "0.5,3,16,4\n1.0,5,16,4\n")
    with _w.catch_warnings():
        _w.simplefilter("error")
        reqs = load_trace(str(p))
    assert [r.rid for r in reqs] == [0, 1]


def test_cluster_affinity_beats_round_robin_under_skew():
    """Acceptance: at 256 adapters x 4 replicas under Zipf(1.0) skew and
    saturating load, JD-cluster-affinity routing >= round-robin throughput
    (both modes; the lora gap is the bigger one — swap traffic halves)."""
    from repro.configs import get_config
    from repro.serving.engine import ServingHardware
    from repro.serving.simulator import build_fleet, memory_matched_setup

    cfg = get_config("mistral-7b")
    n = 256
    wl = WorkloadSpec(n_requests=400, n_adapters=n, new_tokens=10,
                      popularity="zipf", zipf_alpha=1.0,
                      arrival="poisson", arrival_rate=2000.0)
    setting, cluster_of, budget = memory_matched_setup(cfg, n)

    def rps(mode, policy):
        fl = build_fleet(cfg, mode, n, budget,
                         FleetConfig(n_replicas=4, policy=policy),
                         ServingHardware(), cluster_of, setting)
        fl.submit(make_workload(wl))
        return fl.run().total.throughput_rps

    assert rps("jd", "cluster_affinity") >= rps("jd", "round_robin")
    assert rps("lora", "cluster_affinity") >= rps("lora", "round_robin")


# ---------------------------------------------------------------------------
# prefetch priority fix
# ---------------------------------------------------------------------------


def test_prefetch_does_not_block_demand_load():
    dma = DMAModel(bandwidth=100.0, latency=0.0)   # 1 byte = 10 ms
    c = AdapterCache(CacheConfig(capacity_bytes=1000, dma=dma))
    c.prefetch(1, 500, now=0.0)                     # background: done at 5.0
    t = c.ensure(2, 100, now=0.0)                   # demand right after
    # demand load preempts: ready at 1.0, NOT queued behind the prefetch
    assert t == pytest.approx(1.0)
    assert c.n_swaps == 1 and c.n_prefetches == 1
    # promoted prefetch becomes usable at its own completion time
    assert c.ensure(1, 500, now=2.0) == pytest.approx(5.0)
    # once landed, it's free
    assert c.ensure(1, 500, now=6.0) == 6.0


def test_prefetch_never_evicts():
    c = AdapterCache(CacheConfig(capacity_bytes=100))
    c.ensure(1, 80, now=0.0)
    c.prefetch(2, 50, now=1.0)       # would need eviction: dropped
    assert not c.is_resident(2) and c.is_resident(1)
    c.prefetch(3, 20, now=1.0)       # fits in the slack: loaded
    assert c.is_resident(3)


def test_demand_miss_after_multiple_prefetches_not_queued_behind_them():
    """Prefetches serialize among themselves, but a demand miss issued right
    after any number of prefetches preempts the whole background queue."""
    dma = DMAModel(bandwidth=100.0, latency=0.0)    # 1 byte = 10 ms
    c = AdapterCache(CacheConfig(capacity_bytes=1000, dma=dma))
    c.prefetch(1, 200, now=0.0)                     # background: done at 2.0
    c.prefetch(2, 100, now=0.0)                     # queued behind 1: 3.0
    t = c.ensure(3, 100, now=0.0)                   # demand right after
    assert t == pytest.approx(1.0)                  # not 4.0
    # first prefetch lands at its background time (a cold re-fetch would be
    # slower: copy engine busy until 1.0 + 2.0s transfer)
    assert c.ensure(1, 200, now=0.0) == pytest.approx(2.0)
    # second prefetch is stuck behind the first (3.0); promotion re-issues
    # it on the demand path instead: ready at 1.0 + 1.0s — never worse
    # than a cold demand load
    assert c.ensure(2, 100, now=0.0) == pytest.approx(2.0)
    assert c.n_prefetches == 2 and c.n_swaps == 2


def test_demand_eviction_prefers_prefetched_over_demand_resident():
    """Prefetched entries enter the LRU cold end: when a demand load needs
    space it evicts them before any demand-loaded adapter."""
    c = AdapterCache(CacheConfig(capacity_bytes=100))
    c.ensure(1, 50, now=0.0)         # resident demand adapter
    c.prefetch(2, 40, now=1.0)       # speculative fill
    c.ensure(3, 40, now=2.0)         # needs 40 bytes: evict the prefetch
    assert c.is_resident(1) and c.is_resident(3)
    assert not c.is_resident(2)


def test_prefetch_never_evicts_inflight_prefetches_either():
    """A prefetch that would need to displace anything — demand-resident or
    previously prefetched — is dropped instead."""
    c = AdapterCache(CacheConfig(capacity_bytes=100))
    c.ensure(1, 50, now=0.0)
    c.prefetch(2, 30, now=1.0)       # fits
    c.prefetch(3, 30, now=1.0)       # would displace: dropped
    assert c.is_resident(1) and c.is_resident(2)
    assert not c.is_resident(3)
    assert c.n_prefetches == 1


def test_prefetch_of_resident_adapter_is_noop():
    c = AdapterCache(CacheConfig(capacity_bytes=100))
    c.ensure(1, 50, now=0.0)
    c.prefetch(1, 50, now=1.0)
    assert c.n_prefetches == 0 and c.used_bytes == 50
    # and a resident demand adapter is never double-charged
    c.prefetch(2, 40, now=1.0)
    c.prefetch(2, 40, now=1.5)
    assert c.n_prefetches == 1 and c.used_bytes == 90


def test_adaptive_prefetch_depth_follows_queue():
    """With prefetch_depth=None (adaptive) the lookahead tracks the routed
    queue: a deeper backlog of distinct adapters warms more of them ahead
    (n_prefetches grows with queue depth, not with a static cap)."""
    def run(n_queued):
        eng = ServingEngine(
            EngineConfig(scheduler=SchedulerConfig(max_batch=1),
                         adapter_budget_bytes=1e9, prefetch=True),
            FixedCostExecutor(prefill=0.01, decode=0.01))
        eng.cache = AdapterCache(CacheConfig(1e9, DMAModel(bandwidth=1e6,
                                                           latency=0.0)))
        reqs = [Request(rid=i, adapter_id=i, prompt_len=8, max_new_tokens=2,
                        arrival_time=0.0) for i in range(n_queued)]
        eng.submit(reqs)
        eng.run()
        return eng.cache.n_prefetches

    shallow, deep = run(4), run(12)
    assert deep > shallow
    # the old static default (4) could never have prefetched this much
    assert deep > 4


def test_engine_prefetch_reduces_stall_not_throughput():
    def run(prefetch):
        eng = ServingEngine(
            EngineConfig(scheduler=SchedulerConfig(max_batch=2),
                         adapter_budget_bytes=1e9, prefetch=prefetch,
                         prefetch_depth=8),
            FixedCostExecutor(prefill=0.01, decode=0.01))
        # slow DMA: misses hurt unless warmed ahead of admission
        eng.cache = AdapterCache(CacheConfig(1e9, DMAModel(bandwidth=1e4,
                                                           latency=0.0)))
        reqs = [Request(rid=i, adapter_id=i, prompt_len=8, max_new_tokens=4,
                        arrival_time=0.0) for i in range(12)]
        eng.submit(reqs)
        return eng.run()
    cold, warm = run(False), run(True)
    assert warm.swap_time <= cold.swap_time
    assert warm.wall_time <= cold.wall_time
    assert warm.n_requests == cold.n_requests == 12
