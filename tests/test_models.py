"""Per-arch smoke tests (reduced same-family configs) + decode consistency.

Every assigned architecture instantiates a REDUCED config, runs one forward/
train step on CPU, asserts output shapes + finite values; a representative
subset also checks prefill+decode == full-forward logits.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, smoke_config, smoke_shape
from repro.models import api, transformer as tf
from repro.models.layers import logits_fwd
from repro.models.param import init_params


def _make_batch(cfg, shape, key):
    out = {}
    for k, v in api.batch_struct(cfg, shape).items():
        if v.dtype == jnp.int32:
            out[k] = jax.random.randint(key, v.shape, 0, cfg.vocab_size)
        else:
            out[k] = jax.random.normal(key, v.shape, jnp.float32).astype(v.dtype)
    return out


FAST_ARCHS = ("qwen3-1.7b", "qwen3-32b", "mistral-large-123b")


def _lane(archs):
    """Heavy reduced-arch params run in the slow CI lane only."""
    return [pytest.param(a, marks=[] if a in FAST_ARCHS else
                         [pytest.mark.slow]) for a in archs]


@pytest.mark.parametrize("arch", _lane(ASSIGNED))
def test_smoke_train_step(arch):
    cfg = smoke_config(arch)
    defs = tf.model_defs(cfg)
    params = init_params(defs, jax.random.PRNGKey(0))
    batch = _make_batch(cfg, smoke_shape("train"), jax.random.PRNGKey(1))
    loss = tf.lm_loss(params, batch, cfg)
    assert jnp.isfinite(loss), arch
    # random init => loss near ln(V)
    assert abs(float(loss) - math.log(cfg.vocab_size)) < 1.5, float(loss)
    # one gradient step moves the loss
    from repro.training.step import make_train_step
    grad_step = make_train_step(cfg, with_opt=False)
    l2, grads = grad_step(params, batch)
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert jnp.isfinite(l2) and gn > 0


@pytest.mark.parametrize("arch", _lane(["qwen3-1.7b", "deepseek-moe-16b",
                                        "mamba2-2.7b", "zamba2-2.7b",
                                        "whisper-small", "pixtral-12b"]))
def test_decode_matches_full_forward(arch):
    cfg = smoke_config(arch)
    defs = tf.model_defs(cfg)
    params = init_params(defs, jax.random.PRNGKey(0))
    B, S_prompt, S_max = 2, 16, 32
    key = jax.random.PRNGKey(1)
    enc_len = S_prompt if cfg.family == "audio" else 0
    cache = tf.init_cache(cfg, B, S_max, enc_len=enc_len)
    batch = {"tokens": jax.random.randint(key, (B, S_prompt), 0,
                                          cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            key, (B, 4, cfg.d_model)).astype(jnp.bfloat16)
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            key, (B, S_prompt, cfg.d_model)).astype(jnp.bfloat16)
    _, cache = tf.prefill(params, batch, cfg, cache)
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, 3), 0,
                              cfg.vocab_size)
    lg = None
    for i in range(3):
        lg, cache = tf.decode_step(params, toks[:, i:i + 1], cfg, cache)
    full = dict(batch)
    full["tokens"] = jnp.concatenate([batch["tokens"], toks], 1)
    h, _, _ = tf.forward(params, cfg, tokens=full["tokens"],
                         patches=full.get("patches"),
                         frames=full.get("frames"), mode="train")
    ref = logits_fwd(params["embed"], h[:, -1:], cfg)
    err = float(jnp.max(jnp.abs(lg.astype(jnp.float32)
                                - ref.astype(jnp.float32))))
    assert err < 0.1, (arch, err)


@pytest.mark.parametrize("arch", ASSIGNED)
def test_full_config_param_counts(arch):
    """The FULL configs' analytic param counts land in the advertised class
    (sanity that configs/<id>.py match the public architecture)."""
    from repro.configs import get_config
    cfg = get_config(arch)
    n = cfg.param_count()
    expected = {
        "deepseek-moe-16b": (14e9, 19e9),
        "granite-moe-3b-a800m": (2.5e9, 4e9),
        "qwen3-32b": (28e9, 36e9),
        "qwen3-1.7b": (1.6e9, 2.4e9),
        "mistral-large-123b": (110e9, 130e9),
        "qwen1.5-110b": (100e9, 120e9),
        "zamba2-2.7b": (2.2e9, 3.3e9),
        "pixtral-12b": (11e9, 14e9),
        "mamba2-2.7b": (2.3e9, 3.1e9),
        "whisper-small": (0.2e9, 0.35e9),
    }[arch]
    assert expected[0] < n < expected[1], (arch, n)


def test_per_slot_decode_positions():
    """Continuous-batching path: per-row cache indices decode correctly."""
    cfg = smoke_config("qwen3-1.7b")
    defs = tf.model_defs(cfg)
    params = init_params(defs, jax.random.PRNGKey(0))
    B, S_max = 2, 32
    key = jax.random.PRNGKey(1)
    toks = jax.random.randint(key, (B, 10), 0, cfg.vocab_size)
    # reference: scalar-index batch decode of both rows together
    cache = tf.init_cache(cfg, B, S_max)
    _, cache = tf.prefill(params, {"tokens": toks}, cfg, cache)
    nxt = jax.random.randint(jax.random.PRNGKey(2), (B, 1), 0, cfg.vocab_size)
    ref_lg, _ = tf.decode_step(params, nxt, cfg, cache)
    # per-row: same lengths expressed as a vector index
    cache2 = tf.init_cache(cfg, B, S_max)
    _, cache2 = tf.prefill(params, {"tokens": toks}, cfg, cache2)
    cache2["index"] = jnp.full((B,), 10, jnp.int32)
    lg, _ = tf.decode_step(params, nxt, cfg, cache2)
    np.testing.assert_allclose(np.asarray(lg, np.float32),
                               np.asarray(ref_lg, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_lora_modes_consistent():
    """single / batched / jd application paths agree when constructed to
    represent the same adapter."""
    cfg = smoke_config("qwen3-1.7b")
    defs = tf.model_defs(cfg)
    params = init_params(defs, jax.random.PRNGKey(0))
    from repro.models.lora import LoRAContext
    from repro.models.transformer import lora_defs_tree
    lp = init_params(lora_defs_tree(cfg), jax.random.PRNGKey(3),
                     dtype_override=jnp.float32)
    # make b nonzero so the delta matters
    lp = jax.tree.map(lambda x: x + 0.01, lp)
    key = jax.random.PRNGKey(4)
    toks = jax.random.randint(key, (2, 8), 0, cfg.vocab_size)

    def fwd(mode, lora_params, ids=None, scaling=1.0):
        proto = LoRAContext(mode=mode, params=None, ids=ids, scaling=scaling)
        h, _, _ = tf.forward(params, cfg, tokens=toks, mode="train",
                             lora_params=lora_params, lora_ctx_proto=proto)
        return h

    h_single = fwd("single", lp, scaling=2.0)
    # batched bank with n=3 where adapter 1 == the single adapter (x2 scale
    # folded into B)
    bank = {"layers": {tgt: {
        "A": jnp.stack([jnp.zeros_like(lp["layers"][tgt]["a"]),
                        lp["layers"][tgt]["a"],
                        jnp.ones_like(lp["layers"][tgt]["a"])], axis=1),
        "B": jnp.stack([jnp.zeros_like(lp["layers"][tgt]["b"]),
                        lp["layers"][tgt]["b"] * 2.0,
                        jnp.ones_like(lp["layers"][tgt]["b"])], axis=1),
    } for tgt in lp["layers"]}}
    ids = jnp.array([1, 1], jnp.int32)
    h_batched = fwd("batched", bank, ids=ids)
    np.testing.assert_allclose(np.asarray(h_single, np.float32),
                               np.asarray(h_batched, np.float32),
                               rtol=3e-2, atol=3e-2)
