"""RealModelExecutor decode-path parity: the fused and fused_q8 paths must
reproduce the unfused (baseline-bit-exact) path on a reduced model, and the
engine must refuse a decode-path mismatch between config and executor."""
import dataclasses as dc

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models import transformer as tf
from repro.models.param import init_params
from repro.serving.engine import EngineConfig, ModelFootprint, ServingEngine
from repro.serving.real_executor import (DECODE_PATHS, RealModelExecutor,
                                         derive_cost_constants)
from repro.serving.request import Request
from repro.serving.scheduler import SchedulerConfig


@pytest.fixture(scope="module")
def setup():
    cfg = dc.replace(smoke_config("mistral-7b"), num_layers=2, d_model=64,
                     num_heads=2, num_kv_heads=1, d_ff=128, vocab_size=64)
    params = init_params(tf.model_defs(cfg), jax.random.PRNGKey(0))
    L, n, r = cfg.num_layers, 4, 8
    d, hd = cfg.d_model, cfg.resolved_head_dim
    dims = {"q": (d, cfg.num_heads * hd), "k": (d, cfg.num_kv_heads * hd),
            "v": (d, cfg.num_kv_heads * hd), "o": (cfg.num_heads * hd, d)}
    ks = jax.random.split(jax.random.PRNGKey(7), 2 * len(dims))
    bundles = {"layers": {}}
    for i, (t, (di, do)) in enumerate(dims.items()):
        bundles["layers"][t] = {
            "A": 0.05 * jax.random.normal(ks[2 * i], (L, n, r, di),
                                          jnp.float32),
            "B": 0.05 * jax.random.normal(ks[2 * i + 1], (L, n, do, r),
                                          jnp.float32)}
    return cfg, params, bundles, n


def _executor(setup, path):
    cfg, params, bundles, n = setup
    return RealModelExecutor(cfg, params, bundles, "lora", max_batch=8,
                             s_max=64, decode_path=path)


def _prefill_all(ex, n, prompts):
    for rid, prompt in prompts.items():
        ex.prefill_request(Request(rid=rid, adapter_id=rid % n,
                                   prompt_len=len(prompt),
                                   max_new_tokens=8), prompt)


def _prompts(count=4):
    rng = np.random.default_rng(0)
    return {rid: rng.integers(0, 36, size=6 + rid).astype(np.int32)
            for rid in range(count)}


def test_fused_path_matches_unfused_tokens_and_logits(setup):
    cfg, params, bundles, n = setup
    prompts = _prompts()
    e_u, e_f = _executor(setup, "unfused"), _executor(setup, "fused")
    _prefill_all(e_u, n, prompts)
    _prefill_all(e_f, n, prompts)
    tokens = jnp.asarray(e_u.slot_tokens[:, None])
    ids = jnp.asarray(e_u.slot_adapter)
    l_u, _ = e_u._decode(e_u.params, e_u.bundles, tokens, e_u.cache, ids)
    l_f, _ = e_f._decode(e_f.params, e_f.bundles, tokens, e_f.cache, ids,
                         bucket=e_f._bucket())
    # one bf16 ulp at logit magnitude; the argmax stream is identical below
    np.testing.assert_allclose(np.asarray(l_u, np.float32),
                               np.asarray(l_f, np.float32),
                               rtol=0, atol=8e-3)
    e_u2, e_f2 = _executor(setup, "unfused"), _executor(setup, "fused")
    _prefill_all(e_u2, n, prompts)
    _prefill_all(e_f2, n, prompts)
    for _ in range(4):
        assert e_u2.decode_step_real() == e_f2.decode_step_real()


def test_fused_q8_shrinks_residency_and_stays_close(setup):
    cfg, params, bundles, n = setup
    e_f, e_q = _executor(setup, "fused"), _executor(setup, "fused_q8")
    ratio = e_f.adapter_bytes(0) / e_q.adapter_bytes(0)
    assert ratio >= 3.0, ratio                 # int8 + per-channel scales
    prompts = _prompts()
    _prefill_all(e_f, n, prompts)
    _prefill_all(e_q, n, prompts)
    tokens = jnp.asarray(e_f.slot_tokens[:, None])
    ids = jnp.asarray(e_f.slot_adapter)
    l_f, _ = e_f._decode(e_f.params, e_f.bundles, tokens, e_f.cache, ids,
                         bucket=e_f._bucket())
    l_q, _ = e_q._decode(e_q.params, e_q.bundles, tokens, e_q.cache, ids,
                         bucket=e_q._bucket())
    err = float(np.max(np.abs(np.asarray(l_f, np.float32)
                              - np.asarray(l_q, np.float32))))
    assert err < 0.5, err                      # rel-err gate territory


def test_engine_rejects_decode_path_mismatch(setup):
    ex = _executor(setup, "fused")
    with pytest.raises(ValueError, match="decode_path"):
        ServingEngine(EngineConfig(scheduler=SchedulerConfig(max_batch=8),
                                   mode="lora", decode_path="unfused"), ex)
    with pytest.raises(ValueError):
        _executor(setup, "nope")
    assert set(DECODE_PATHS) == {"unfused", "fused", "fused_q8"}


def test_footprint_adapter_bits_pricing():
    cfg = smoke_config("mistral-7b")
    fp16 = ModelFootprint.from_config(cfg, rank=16)
    fp8 = ModelFootprint.from_config(cfg, rank=16, adapter_bits=8)
    # vs bf16 the value bytes halve; per-channel f32 scales claw a bit back
    assert fp8.lora_bytes_per_adapter < fp16.lora_bytes_per_adapter / 1.6
    assert fp8.jd_shared_bytes_per_cluster < fp16.jd_shared_bytes_per_cluster
    with pytest.raises(ValueError):
        ModelFootprint.from_config(cfg, adapter_bits=4)


def test_derive_cost_constants_fits_affine_model():
    samples = [(b, 1e-3 + 2e-4 * b) for b in (1, 2, 4, 8)]
    got = derive_cost_constants(samples)
    assert abs(got["step_overhead_s"] - 1e-3) < 1e-7
    assert abs(got["per_slot_s"] - 2e-4) < 1e-8
    assert got["r2"] > 0.999 and got["n_samples"] == 4
    with pytest.raises(ValueError):
        derive_cost_constants([(4, 1.0), (4, 1.1)])
