"""Typed hardware slices + rank-aware placement (docs/architecture.md §9).

Covers the four §9 invariants — H1 per-type conservation, H2
no-overcommit, H3 legacy single-type equivalence (down to bit-exact
reproduction of the committed joint/adaptive baselines), H4 the router's
jax-free rank-efficiency mirror of the SGMV tile cost model — plus the
satellites: slice-aware autoscaler type choice, peer-mean routed-load
seeding for mid-run-attached replicas, and the real-decode calibration
constants staying in sync with ``BENCH_real.json``.
"""
import json
import pathlib

import pytest

from repro.serving.adapter_cache import AdapterCache, CacheConfig, DMAModel
from repro.serving.autoscaler import (JointAutoscaler, JointAutoscalerConfig,
                                      SLOConfig)
from repro.serving.engine import (REAL_DECODE_PER_SLOT_S,
                                  REAL_DECODE_STEP_OVERHEAD_S,
                                  CostModelExecutor, EngineConfig,
                                  ModelFootprint, ServingEngine,
                                  ServingHardware)
from repro.serving.request import Request
from repro.serving.resources import BudgetConfig, HardwareBudget, SliceType
from repro.serving.router import Fleet, FleetConfig, rank_efficiency
from repro.serving.scheduler import SchedulerConfig

BASELINES = pathlib.Path(__file__).parent.parent / "benchmarks" / "baselines"

BIG = SliceType("big", cost_units=4, prefill_speed=4.0, decode_speed=2.0,
                sgmv_tile_rank=32)
SMALL = SliceType("small")


def _typed(total=8, types=(BIG, SMALL)):
    return HardwareBudget(BudgetConfig(slice_types=tuple(types),
                                       total_cost_units=total))


# ---------------------------------------------------------------------------
# H1: conservation — in_use + available == total_units, per-type ledger
# ---------------------------------------------------------------------------


def test_typed_ledger_conserves_units():  # H1
    b = _typed(total=8)
    assert b.in_use == 0 and b.available == 8
    b.allocate("prefill", BIG)
    b.allocate("decode", SMALL)
    b.allocate("decode", SMALL)
    assert b.in_use == 6 and b.available == 2
    assert b.in_use + b.available == b.cfg.total_units  # H1
    assert b.count("decode", SMALL) == 2
    assert b.count("decode", BIG) == 0
    assert b.count("decode") == 2 and b.count("prefill") == 1
    b.release("decode", SMALL)
    assert b.in_use + b.available == b.cfg.total_units  # H1
    assert b.available == 3
    b.release("prefill", BIG)
    assert b.in_use == 1 and b.available == 7


def test_typed_footprints_price_in_cost_units():  # H1
    fat = SliceType("fat", cost_units=2, prefill_slices=3, decode_slices=1)
    b = _typed(total=12, types=(fat,))
    assert b.cfg.cost("prefill", fat) == 6      # 2 units x 3 slices
    assert b.cfg.cost("decode", fat) == 2
    b.allocate("prefill", fat)
    b.allocate("decode", fat)
    assert b.in_use == 8 and b.available == 4
    assert b.allocated == {"prefill": 1, "decode": 1}


def test_legacy_allocated_view_sums_types():  # H1
    b = _typed(total=8)
    b.allocate("decode", BIG)
    b.allocate("decode", SMALL)
    assert b.allocated == {"prefill": 0, "decode": 2}
    assert b.to_dict()["slices"]["decode"] == {"big": 1, "small": 1}


# ---------------------------------------------------------------------------
# H2: no overcommit — exhaustion raises, bad releases raise
# ---------------------------------------------------------------------------


def test_typed_exhaustion_raises():  # H2
    b = _typed(total=5)
    b.allocate("prefill", BIG)              # 1 unit left
    assert b.can_allocate("decode", SMALL)
    assert not b.can_allocate("decode", BIG)
    with pytest.raises(MemoryError):
        b.allocate("decode", BIG)           # would need 4 > 1
    b.allocate("decode", SMALL)
    assert b.available == 0
    assert not b.can_allocate("decode")     # even the cheapest type
    with pytest.raises(MemoryError):
        b.allocate("decode", SMALL)


def test_typed_release_requires_live_allocation():  # H2
    b = _typed(total=8)
    b.allocate("decode", SMALL)
    with pytest.raises(ValueError, match="no decode allocation"):
        b.release("decode", BIG)            # type never allocated
    with pytest.raises(ValueError, match="no prefill allocation"):
        b.release("prefill", SMALL)
    # sole-held-type release may omit the type; ambiguous may not
    b.release("decode")
    b.allocate("decode", SMALL)
    b.allocate("decode", BIG)
    with pytest.raises(ValueError, match="unknown slice type"):
        b.release("decode", SliceType("other"))
    with pytest.raises(ValueError):
        b.release("decode")                 # two types held: ambiguous


def test_typed_pool_validation():  # H2
    with pytest.raises(ValueError, match="explicit slice type"):
        _typed().allocate("decode")         # typed pool: must name a type
    with pytest.raises(ValueError, match="unknown slice type"):
        _typed().allocate("decode", SliceType("tpu9"))
    with pytest.raises(ValueError, match="duplicate"):
        _typed(types=(SMALL, SliceType("small", cost_units=2)))
    with pytest.raises(ValueError, match="unknown role"):
        _typed().allocate("train", SMALL)
    with pytest.raises(ValueError):
        HardwareBudget(BudgetConfig(slice_types=(SMALL,),
                                    total_cost_units=0))


# ---------------------------------------------------------------------------
# H3: a single-type pool is arithmetically the legacy budget
# ---------------------------------------------------------------------------


def test_single_type_pool_matches_legacy_ledger():  # H3
    legacy = HardwareBudget(BudgetConfig(total_accelerators=6,
                                         prefill_accels_per_worker=2))
    accel = SliceType("accel", prefill_slices=2)
    typed = HardwareBudget(BudgetConfig(slice_types=(accel,),
                                        total_cost_units=6))
    trace = [("allocate", "prefill"), ("allocate", "decode"),
             ("allocate", "decode"), ("release", "decode"),
             ("allocate", "prefill")]
    for op, role in trace:
        getattr(legacy, op)(role)
        getattr(typed, op)(role, accel)
        assert typed.in_use == legacy.in_use
        assert typed.available == legacy.available
        assert typed.allocated == legacy.allocated
    assert not legacy.can_allocate("prefill")   # 1 free < 2-accel footprint
    assert not typed.can_allocate("prefill", accel)


def test_joint_auto_cell_bit_exact_with_committed_baseline():  # H3
    """The refactored budget/autoscaler/router stack reproduces PR 3's
    committed jointly-autoscaled cell bit-exactly through the legacy
    untyped config."""
    from benchmarks.joint_budget import joint_cell, phase_shift_workload
    from repro.configs import get_config

    reqs = phase_shift_workload(alpha=1.0)[:1000]   # the quick cell
    stats = joint_cell(get_config("mistral-7b"), reqs, 6, 0.4)
    with open(BASELINES / "BENCH_joint.json") as f:
        baseline = json.load(f)
    assert stats.total.throughput_rps == pytest.approx(
        baseline["joint_zipf1.0_b6_fab50g_auto"]["rps"], rel=1e-12)


def test_typed_single_slice_joint_cell_bit_exact():  # H3
    """The same jointly-autoscaled cell run through the *typed* path — a
    one-type pool of unit-cost unit-speed slices, typed fleet, typed
    factories — lands on the identical committed number: the typed
    machinery is a strict generalization, not a reimplementation."""
    from benchmarks.joint_budget import N_ADAPTERS, phase_shift_workload
    from repro.configs import get_config
    from repro.serving.prefill import PrefillConfig
    from repro.serving.simulator import run_elastic_study

    accel = SliceType("accel")
    stats = run_elastic_study(
        get_config("mistral-7b"), "jd", N_ADAPTERS,
        phase_shift_workload(alpha=1.0)[:1000],
        FleetConfig(n_replicas=2, policy="cluster_affinity"),
        prefill_cfg=PrefillConfig(n_workers=2),
        slo=SLOConfig(ttft_p95=0.4),
        budget_cfg=BudgetConfig(slice_types=(accel,), total_cost_units=6),
        joint_cfg=JointAutoscalerConfig(decision_interval=0.05,
                                        cooldown_intervals=0),
        decode_slice_types=[accel, accel], prefill_slice_type=accel)
    with open(BASELINES / "BENCH_joint.json") as f:
        baseline = json.load(f)
    assert stats.total.throughput_rps == pytest.approx(
        baseline["joint_zipf1.0_b6_fab50g_auto"]["rps"], rel=1e-12)


def test_adaptive_joint_axis_cell_bit_exact_with_baseline():  # H3
    """PR 6's compression-axis cell (joint budget + adaptive ladder) is
    untouched by the typed-slice refactor."""
    from benchmarks.adaptive_compression import (adaptive_workload,
                                                 joint_axis_cell)
    from repro.configs import get_config

    stats = joint_axis_cell(get_config("mistral-7b"), adaptive_workload(4.0),
                            2e9)
    with open(BASELINES / "BENCH_adaptive.json") as f:
        baseline = json.load(f)
    assert stats.total.throughput_rps == pytest.approx(
        baseline["adaptive_joint_axis_b6_bw2g"]["rps"], rel=1e-12)


# ---------------------------------------------------------------------------
# H4: the router's rank-efficiency mirror of the SGMV tile cost model
# ---------------------------------------------------------------------------


def test_router_rank_efficiency_mirrors_sgmv_kernel_model():  # H4
    sgmv = pytest.importorskip("repro.kernels.sgmv")
    for tile in (1, 4, 8, 16, 32):
        for rank in range(1, 66):
            assert rank_efficiency(rank, tile) == \
                sgmv.sgmv_rank_efficiency(rank, tile)
            cost = sgmv.sgmv_tile_cost(rank, tile)
            assert cost % tile == 0 and rank <= cost < rank + tile


def test_rank_efficiency_properties():  # H4
    assert rank_efficiency(8, 8) == 1.0      # tile multiple: no padding
    assert rank_efficiency(16, 8) == 1.0
    assert rank_efficiency(4, 8) == 0.5      # half the tile streams zeros
    assert rank_efficiency(1, 32) == 1 / 32  # worst case: 1/tile
    assert rank_efficiency(5, 1) == 1.0      # tile 1: unpadded identity
    with pytest.raises(ValueError):
        rank_efficiency(0)
    with pytest.raises(ValueError):
        rank_efficiency(8, 0)


# ---------------------------------------------------------------------------
# slice-scaled hardware and the per-rank adapter byte model
# ---------------------------------------------------------------------------


def test_for_slice_scales_rooflines():
    hw = ServingHardware()
    fast = hw.for_slice(SliceType("x", prefill_speed=2.0, decode_speed=3.0,
                                  hbm_bytes=1e9))
    assert fast.peak_flops == hw.peak_flops * 2.0
    assert fast.hbm_bw == hw.hbm_bw * 3.0
    assert fast.hbm_bytes == 1e9
    inherit = hw.for_slice(SliceType("y"))
    assert inherit.hbm_bytes == hw.hbm_bytes
    assert hw.for_slice(None) is hw          # untyped: identity, bit-exact


def _fp(lora_bytes=1600, lora_rank=16):
    return ModelFootprint(n_active_params=1, weight_bytes=0,
                          lora_bytes_per_adapter=lora_bytes,
                          jd_shared_bytes_per_cluster=0,
                          jd_sigma_bytes_per_adapter=0,
                          kv_bytes_per_token=1, lora_rank=lora_rank)


def test_lora_adapter_bytes_scale_with_padded_rank():
    hw = ServingHardware()
    ex = CostModelExecutor(hw, _fp(), "lora", rank_of={1: 4, 2: 48},
                           slice_type=SliceType("w", sgmv_tile_rank=8))
    assert ex.lora_adapter_bytes(1) == 1600 * 8 // 16    # rank 4 -> tile 8
    assert ex.lora_adapter_bytes(2) == 1600 * 48 // 16   # 48 = 6 tiles
    assert ex.lora_adapter_bytes(99) == 1600             # unmapped: fp rank
    # no rank map: legacy constant bytes regardless of slice (H3)
    legacy = CostModelExecutor(hw, _fp(), "lora",
                               slice_type=SliceType("w", sgmv_tile_rank=8))
    assert legacy.lora_adapter_bytes(1) == 1600
    # rank map but no slice: unpadded (tile 1) scaling
    flat = CostModelExecutor(hw, _fp(), "lora", rank_of={1: 4})
    assert flat.lora_adapter_bytes(1) == 1600 * 4 // 16


# ---------------------------------------------------------------------------
# autoscaler slice-type choice (satellite)
# ---------------------------------------------------------------------------


def _joint_typed(total=8, types=(BIG, SMALL), **kw):
    budget = HardwareBudget(BudgetConfig(slice_types=tuple(types),
                                         total_cost_units=total))
    cfg = JointAutoscalerConfig(cooldown_intervals=0, **kw)
    return JointAutoscaler(cfg, SLOConfig(ttft_p95=1.0), budget), budget


def test_pick_slice_prefill_prefers_compute_decode_prefers_bw_per_unit():
    a, _ = _joint_typed(total=8)
    # prefill: fastest compute first (big: 4x), affordable at 8 free
    assert a.pick_slice("prefill").name == "big"
    # decode: bandwidth per cost unit (small 1.0/1 beats big 2.0/4)
    assert a.pick_slice("decode").name == "small"


def test_pick_slice_falls_back_to_cheapest_when_pool_tight():
    a, b = _joint_typed(total=5)
    b.allocate("prefill", BIG)               # 1 unit free: big unaffordable
    assert a.pick_slice("prefill").name == "small"
    # extra_units from a would-be trade makes big affordable again
    assert a.pick_slice("prefill", extra_units=3).name == "big"
    b.allocate("decode", SMALL)              # 0 free: nothing affordable
    assert a.pick_slice("prefill").name == "small"   # cheapest fallback
    assert a.pick_slice("decode") is not None


def test_untyped_pick_slice_is_none():  # H3
    budget = HardwareBudget(BudgetConfig(total_accelerators=4))
    a = JointAutoscaler(JointAutoscalerConfig(cooldown_intervals=0),
                        SLOConfig(ttft_p95=1.0), budget)
    assert a.pick_slice("prefill") is None
    assert a.pick_slice("decode") is None


def test_decision_records_chosen_slice_per_phase():
    # prefill-heavy phase: scale-up from the free pool picks the big slice
    a, b = _joint_typed(total=12)
    b.allocate("prefill", BIG)
    b.allocate("decode", SMALL)
    d = a.decide(1.0, [0.6] * 20, [], [0.05] * 20, [0.9] * 20,
                 n_prefill=1, n_decode=1,
                 prefill_backlog=0, decode_backlog=0)
    assert d == (1, 0)
    assert a.history[-1].prefill_slice == "big"
    assert a.history[-1].decode_slice is None
    # decode-heavy phase: the decode grow picks the small slice
    a2, b2 = _joint_typed(total=12)
    b2.allocate("prefill", BIG)
    b2.allocate("decode", SMALL)
    d2 = a2.decide(1.0, [0.8] * 20, [], [0.7] * 20, [0.05] * 20,
                   n_prefill=1, n_decode=1,
                   prefill_backlog=0, decode_backlog=0)
    assert d2 == (0, 1)
    assert a2.history[-1].decode_slice == "small"


def test_typed_trade_prices_donor_units_not_replica_counts():
    # pool full: 1 big prefill + 4 small decode on 8 units; prefill
    # drowning.  Retiring one small decode frees 1 unit — not enough to
    # fund the small prefill the picker would then choose?  It IS enough
    # (small costs 1), so the trade fires and is priced in units.
    a, b = _joint_typed(total=8)
    b.allocate("prefill", BIG)
    for _ in range(4):
        b.allocate("decode", SMALL)
    d = a.decide(1.0, [0.6] * 20, [], [0.05] * 20, [0.9] * 20,
                 n_prefill=1, n_decode=4,
                 prefill_backlog=9, decode_backlog=1,
                 retire_decode_units=1)
    assert d == (1, -1)
    assert a.history[-1].prefill_slice == "small"
    # same shape but the receiver needs more units than the donor frees:
    # 2-unit-footprint prefill slices only — one freed decode unit cannot
    # fund them, so no trade (it would crash the driver's allocate)
    wide = SliceType("wide", cost_units=2, prefill_slices=1, decode_slices=1)
    a2, b2 = _joint_typed(total=8, types=(wide,))
    b2.allocate("prefill", wide)
    for _ in range(3):
        b2.allocate("decode", wide)
    assert a2.decide(1.0, [0.6] * 20, [], [0.05] * 20, [0.9] * 20,
                     n_prefill=1, n_decode=3,
                     prefill_backlog=9, decode_backlog=1,
                     retire_decode_units=1) == (0, 0)
    # donor actually frees its full 2-unit slice: trade fires
    assert a2.decide(2.0, [0.6] * 20, [], [0.05] * 20, [0.9] * 20,
                     n_prefill=1, n_decode=3,
                     prefill_backlog=9, decode_backlog=1,
                     retire_decode_units=2) == (1, -1)


# ---------------------------------------------------------------------------
# rank-aware routing (tentpole) + peer-mean load seeding (satellite)
# ---------------------------------------------------------------------------


class FixedCostExecutor:
    """Hand-computable executor: prefill 1s, decode step 0.5s."""

    def adapter_bytes(self, aid):
        return 1

    def shared_bytes(self):
        return 0

    def decode_step_time(self, batch):
        return 0.5 if batch else 0.0

    def prefill_time(self, req):
        return 1.0


def _engine(slice_type=None, max_batch=8):
    eng = ServingEngine(
        EngineConfig(scheduler=SchedulerConfig(max_batch=max_batch),
                     adapter_budget_bytes=1e9),
        FixedCostExecutor(), slice_type=slice_type)
    eng.cache = AdapterCache(CacheConfig(1e9, DMAModel(bandwidth=1e30,
                                                       latency=0.0)))
    return eng


def _reqs(adapters, start_rid=0):
    return [Request(rid=start_rid + i, adapter_id=a, prompt_len=8,
                    max_new_tokens=2, arrival_time=0.0)
            for i, a in enumerate(adapters)]


def test_rank_aware_routes_skinny_ranks_to_narrow_tiles():
    """Equal load, one wide-tile fast replica and one narrow-tile slow
    one: a rank-4 adapter scores 2.0 * 4/32 = 0.25 on the wide slice but
    1.0 * 4/8 = 0.5 on the narrow one -> first sighting goes narrow."""
    f = Fleet(FleetConfig(n_replicas=2, policy="adapter_affinity",
                          rank_aware=True),
              [_engine(BIG), _engine(SMALL)], rank_of={7: 4, 8: 64})
    f.submit(_reqs([7]))
    assert f.assignments[0] == 1             # narrow tile wins rank 4
    # rank 64 = 2 full tiles of 32: wide slice's speed dominates
    # (2.0 * 1.0 vs 1.0 * 1.0)
    f2 = Fleet(FleetConfig(n_replicas=2, policy="adapter_affinity",
                           rank_aware=True),
               [_engine(BIG), _engine(SMALL)], rank_of={7: 4, 8: 64})
    f2.submit(_reqs([8]))
    assert f2.assignments[0] == 0


def test_rank_aware_unmapped_adapter_uses_legacy_tiebreak():  # H3
    f = Fleet(FleetConfig(n_replicas=2, policy="adapter_affinity",
                          rank_aware=True),
              [_engine(BIG), _engine(SMALL)], rank_of={7: 4})
    f.submit(_reqs([3]))                     # not in rank_of
    assert f.assignments[0] == 0             # lowest index, legacy rule


def test_rank_aware_requires_rank_map():
    with pytest.raises(ValueError, match="rank_of"):
        Fleet(FleetConfig(n_replicas=2, rank_aware=True),
              [_engine(), _engine()])


def test_routed_load_seed_validated():
    with pytest.raises(ValueError, match="routed_load_seed"):
        Fleet(FleetConfig(n_replicas=1, routed_load_seed="median"),
              [_engine()])


def test_peer_mean_seed_is_mean_of_active_peers():
    f = Fleet(FleetConfig(n_replicas=2, policy="adapter_affinity",
                          routed_load_seed="peer_mean"),
              [_engine(), _engine()])
    f.submit(_reqs([0, 1] * 4))              # both replicas loaded equally
    loads = [f._routed_load[0], f._routed_load[1]]
    assert min(loads) > 0
    k = f.add_replica(_engine())
    assert f._routed_load[k] == pytest.approx(sum(loads) / 2)
    # legacy default seeds at zero (bit-exact with every baseline)  # H3
    fz = Fleet(FleetConfig(n_replicas=2, policy="adapter_affinity"),
               [_engine(), _engine()])
    fz.submit(_reqs([0, 1] * 4))
    kz = fz.add_replica(_engine())
    assert fz._routed_load[kz] == 0.0


def test_peer_mean_newcomer_gets_work_without_hotspot():
    """Mid-run attach under adapter_affinity: a zero-seeded newcomer
    looks infinitely light, so the very next established-adapter request
    spills onto it (hot spot).  Peer-mean seeding keeps warm adapters
    sticky AND still hands the newcomer work within one window of
    arrivals, with no least_outstanding workaround."""
    def run(seed):
        f = Fleet(FleetConfig(n_replicas=2, policy="adapter_affinity",
                              routed_load_seed=seed),
                  [_engine(), _engine()])
        f.submit(_reqs([0, 1] * 6))
        k = f.add_replica(_engine())
        f.submit(_reqs([0, 1] * 6, start_rid=12))   # one window of traffic
        routed_to_k = [r for r, i in f.assignments.items()
                       if r >= 12 and i == k]
        return k, routed_to_k

    k, hot = run("zero")
    assert len(hot) > 6      # zero seed: the newcomer absorbs the window
    k, fair = run("peer_mean")
    assert 1 <= len(fair) <= 6   # gets work, established homes keep most


# ---------------------------------------------------------------------------
# real-decode calibration constants (satellite)
# ---------------------------------------------------------------------------


def test_real_decode_constants_match_committed_bench():
    with open(BASELINES / "BENCH_real.json") as f:
        derived = json.load(f)["derived"]
    assert REAL_DECODE_STEP_OVERHEAD_S == derived["step_overhead_s"]
    assert REAL_DECODE_PER_SLOT_S == derived["per_slot_s"]


def test_real_calibrated_hardware_profile():
    hw = ServingHardware.real_calibrated()
    assert hw.step_overhead == REAL_DECODE_STEP_OVERHEAD_S
    assert ServingHardware.real_calibrated(
        step_overhead=1e-3).step_overhead == 1e-3
    # live simulated baselines keep the legacy default (bit-exactness)
    assert ServingHardware().step_overhead == 3e-4  # H3


# ---------------------------------------------------------------------------
# typed fleet construction plumbing
# ---------------------------------------------------------------------------


def test_build_fleet_validates_slice_list_length():
    from repro.configs import get_config
    from repro.serving.simulator import (build_fleet, memory_matched_setup)

    cfg = get_config("mistral-7b")
    setting, cluster_of, budget = memory_matched_setup(cfg, 8, 0)
    with pytest.raises(ValueError, match="decode_slice_types"):
        build_fleet(cfg, "lora", 8, budget,
                    FleetConfig(n_replicas=2), ServingHardware(),
                    cluster_of, setting, decode_slice_types=[SMALL])


def test_build_engine_slice_scaling_and_slice_pool():
    from repro.configs import get_config
    from repro.serving.simulator import (build_engine, memory_matched_setup,
                                         slice_pool_bytes, serving_footprint)

    cfg = get_config("mistral-7b")
    setting, cluster_of, budget = memory_matched_setup(cfg, 8, 0)
    hw = ServingHardware()
    st = SliceType("half", hbm_bytes=hw.hbm_bytes / 2, decode_speed=2.0)
    eng = build_engine(cfg, "lora", 8, budget, hw, cluster_of, setting,
                      pool_bytes="slice", slice_type=st)
    fp = serving_footprint(cfg, "lora", 8, setting)
    assert eng.slice_type is st
    assert eng.executor.hw.hbm_bw == hw.hbm_bw * 2.0
    expect = slice_pool_bytes(fp, hw.for_slice(st))
    assert eng.pool.cfg.total_bytes == pytest.approx(expect, rel=0.01)
    # untyped: no scaling, identical executor hardware (H3)
    base = build_engine(cfg, "lora", 8, budget, hw, cluster_of, setting)
    assert base.slice_type is None and base.executor.hw is hw
