"""Online adapter lifecycle tests (serving/lifecycle.py).

The lifecycle invariants asserted here (L1-L5) are specified in
docs/lifecycle.md; the docs CI lane cross-checks the invariant IDs
between that spec and this file.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.cluster import (add_adapter, assign_adapter,
                                _assignment_scores, cluster_jd, drop_adapter,
                                refresh_gate)
from repro.serving.adapter_cache import AdapterCache, CacheConfig, DMAModel
from repro.serving.autoscaler import (HardwareBudget, JointAutoscaler,
                                      JointAutoscalerConfig, SLOConfig)
from repro.serving.engine import EngineConfig, ServingEngine, ServingHardware
from repro.serving.lifecycle import (AdapterLifecycle, ChurnSpec,
                                     CLUSTER_ASSIGNED, GateResult,
                                     LifecycleConfig, make_churn_workload,
                                     RAW_SERVING, RETIRED, run_churn_study)
from repro.serving.request import Request, weight_key
from repro.serving.resources import BudgetConfig
from repro.serving.router import Fleet, FleetConfig
from repro.serving.scheduler import SchedulerConfig
from repro.serving.simulator import (build_fleet, memory_matched_setup,
                                     serving_footprint)
from repro.serving.workload import WorkloadSpec


class TinyJDExecutor:
    """Fixed-cost jd-mode executor with the raw overlay: raw adapters
    weigh 4 bytes, compressed sigmas 1, shared bases 8."""

    def __init__(self, prefill=1.0, decode=0.5):
        self._prefill, self._decode = prefill, decode
        self.raw_ids = set()

    def mark_raw(self, aid):
        self.raw_ids.add(aid)

    def unmark_raw(self, aid):
        self.raw_ids.discard(aid)

    def adapter_bytes(self, aid):
        return 4 if aid in self.raw_ids else 1

    def shared_bytes(self):
        return 8

    def decode_step_time(self, batch):
        return self._decode if batch else 0.0

    def prefill_time(self, req):
        return self._prefill


def _engine(max_batch=8):
    eng = ServingEngine(
        EngineConfig(scheduler=SchedulerConfig(max_batch=max_batch),
                     adapter_budget_bytes=1e9, mode="jd"),
        TinyJDExecutor())
    # zero-cost DMA so clock arithmetic is exact
    eng.cache = AdapterCache(CacheConfig(1e9, DMAModel(bandwidth=1e30,
                                                       latency=0.0)))
    return eng


def _fleet(n=2, policy="round_robin", cluster_of=None):
    cfg = FleetConfig(n_replicas=n, policy=policy, spill_requests=1e9)
    return Fleet(cfg, [_engine() for _ in range(n)], cluster_of)


def _lc(fleet, refresh_interval=1.0, step=0.05, **kw):
    return AdapterLifecycle(
        fleet, LifecycleConfig(refresh_interval=refresh_interval,
                               rollout_step_interval=step), **kw)


def _reqs(adapters, arrivals=None, new_tokens=2, rid0=0):
    arrivals = arrivals or [0.0] * len(adapters)
    return [Request(rid=rid0 + i, adapter_id=a, prompt_len=8,
                    max_new_tokens=new_tokens, arrival_time=t)
            for i, (a, t) in enumerate(zip(adapters, arrivals))]


# ---------------------------------------------------------------------------
# L1: hot register -> immediately raw-servable
# ---------------------------------------------------------------------------


def test_register_serves_immediately_raw():  # L1
    """Invariant L1: a registered adapter is servable before any
    compression work — raw overlay on every executor, request completes."""
    f = _fleet(2)
    lc = _lc(f)
    st = lc.register(100, now=0.0)
    assert st.state == RAW_SERVING and st.epoch == 0
    assert all(100 in eng.executor.raw_ids for eng in f.engines)
    assert 100 in f.cluster_of                     # cluster assigned at once
    reqs = _reqs([100])
    lc.stamp(reqs)
    f.submit(reqs)
    f.run()
    assert reqs[0].done and reqs[0].adapter_epoch == 0
    assert lc.stats.raw_requests == 1


def test_register_ttft_matches_established_adapter():  # L1
    """Invariant L1: no cold-start TTFT cliff — the hot-registered
    adapter's first request pays exactly what an established raw adapter
    pays (same fixed-cost executor; no extra compression stall)."""
    f1 = _fleet(1)
    r_est = _reqs([0])
    f1.submit(r_est)
    f1.run()
    f2 = _fleet(1)
    lc = _lc(f2)
    lc.register(100)
    r_hot = _reqs([100])
    lc.stamp(r_hot)
    f2.submit(r_hot)
    f2.run()
    assert r_hot[0].ttft == r_est[0].ttft


def test_weight_key_epoch0_is_bare_adapter_id():
    r = _reqs([7])[0]
    assert weight_key(r) == 7                      # legacy cache key
    r.adapter_epoch = 2
    assert weight_key(r) == (7, 2)


# ---------------------------------------------------------------------------
# L2: background refresh walks the fleet one replica at a time
# ---------------------------------------------------------------------------


def test_rollout_one_replica_at_a_time():  # L2
    """Invariant L2: a refresh swaps bases on one replica per pacing
    interval; at most one rollout is in flight fleet-wide."""
    f = _fleet(2)
    lc = _lc(f, refresh_interval=1.0, step=0.05)
    lc.register(100)
    lc.tick(1.0)                                   # cadence elapsed
    assert lc.refresh_active
    assert [e.cache.n_swaps for e in f.engines] == [1, 0]   # only replica 0
    lc.tick(1.04)                                  # pacing not yet elapsed
    assert [e.cache.n_swaps for e in f.engines] == [1, 0]
    lc.tick(1.05)                                  # replica 1's turn
    assert [e.cache.n_swaps for e in f.engines] == [1, 1]
    assert not lc.refresh_active and lc.basis_version == 1
    assert lc.stats.n_refreshes == 1
    st = lc.adapters[100]
    assert st.state == CLUSTER_ASSIGNED
    assert all(100 not in e.executor.raw_ids for e in f.engines)


def test_no_second_rollout_while_one_in_flight():  # L2
    f = _fleet(2)
    lc = _lc(f, refresh_interval=0.01, step=1.0)   # pacing >> cadence
    lc.register(100)
    lc.tick(0.5)
    ro = lc.rollout
    assert ro is not None and ro.next_idx == 1
    lc.register(101)
    lc.tick(0.6)                                   # cadence long elapsed
    assert lc.rollout is ro                        # same rollout, no new one


# ---------------------------------------------------------------------------
# L3: gate failure -> rollback, keep serving raw
# ---------------------------------------------------------------------------


def test_failed_gate_rolls_back_all_swapped_replicas():  # L3
    """Invariant L3: a gate failure re-pins the prior basis on every
    replica the rollout touched; the adapter keeps serving raw and a
    later cadence retries successfully."""
    f = _fleet(2)
    calls = []

    def gate(ro, target):
        calls.append(target)
        return GateResult(ok=len(calls) != 2)      # fail on the 2nd replica

    lc = _lc(f, refresh_interval=1.0, step=0.05, gate_fn=gate)
    lc.register(100)
    lc.tick(1.0)
    lc.tick(2.0)                                   # 2nd swap -> gate fails
    assert lc.stats.n_rollbacks == 1
    assert lc.stats.n_gate_failures == 1
    assert lc.rollout is None and lc.basis_version == 0
    # candidate + rollback re-pin on both touched replicas
    assert [e.cache.n_swaps for e in f.engines] == [2, 2]
    st = lc.adapters[100]
    assert st.state == RAW_SERVING                 # still served raw
    assert all(100 in e.executor.raw_ids for e in f.engines)
    reqs = _reqs([100])
    lc.stamp(reqs)
    f.submit(reqs)
    f.run()
    assert reqs[0].done                            # serving uninterrupted
    lc.tick(3.0)                                   # next cadence retries
    lc.tick(3.05)
    assert lc.stats.n_refreshes == 1 and lc.basis_version == 1
    assert lc.adapters[100].state == CLUSTER_ASSIGNED


def test_gate_thresholds_enforced():  # L3
    """A gate verdict above the configured reconstruction-error bound or
    below the agreement floor fails even with ok=True."""
    for bad in (GateResult(ok=True, rel_err=0.9),
                GateResult(ok=True, agreement=0.5)):
        f = _fleet(1)
        lc = _lc(f, gate_fn=lambda ro, t, _g=bad: _g)
        lc.register(100)
        lc.tick(1.0)
        assert lc.stats.n_rollbacks == 1
        assert lc.adapters[100].state == RAW_SERVING


def test_register_during_rollout_waits_for_next_refresh():
    """An adapter registered while a rollout is mid-flight is NOT
    absorbed by it (the candidate basis predates it); the next cadence
    picks it up."""
    f = _fleet(2)
    lc = _lc(f, refresh_interval=1.0, step=0.05)
    lc.register(100)
    lc.tick(1.0)                                   # rollout for 100 starts
    assert lc.refresh_active
    lc.register(101, now=1.01)                     # mid-rollout
    assert (101, 0) not in lc.rollout.adapters
    lc.tick(1.05)                                  # rollout completes
    assert lc.adapters[100].state == CLUSTER_ASSIGNED
    assert lc.adapters[101].state == RAW_SERVING   # still raw, still served
    lc.tick(2.1)
    lc.tick(2.2)
    assert lc.adapters[101].state == CLUSTER_ASSIGNED


# ---------------------------------------------------------------------------
# L4: epoch pinning across updates
# ---------------------------------------------------------------------------


def test_update_inflight_finishes_on_old_epoch():  # L4
    """Invariant L4: requests stamped before an update decode against the
    epoch they started on; the stale epoch's weights release only when
    its last request drains."""
    f = _fleet(1)
    lc = _lc(f)
    lc.register(7)
    old = _reqs([7], new_tokens=8)
    lc.stamp(old)
    f.submit(old)
    f.advance_to(1.2)                              # prefilled, mid-decode
    assert not old[0].done
    lc.update(7, now=1.2)
    assert lc.adapters[7].epoch == 1
    new = _reqs([7], rid0=1)
    lc.stamp(new)
    f.submit(new)
    f.run()
    assert old[0].adapter_epoch == 0 and new[0].adapter_epoch == 1
    assert weight_key(old[0]) == 7 and weight_key(new[0]) == (7, 1)
    # stale epoch-0 weights were discarded when the old request drained
    assert not f.engines[0].cache.is_resident(7)
    assert f.engines[0].cache.is_resident((7, 1))
    assert lc.stats.bytes_released > 0
    assert lc.stats.n_updated == 1


def test_retire_while_inflight_drains_on_old_epoch():  # L4
    """A retired adapter's in-flight request finishes on the epoch it was
    stamped with; releases happen only after the drain."""
    f = _fleet(1)
    lc = _lc(f)
    lc.register(9)
    inflight = _reqs([9], new_tokens=8)
    lc.stamp(inflight)
    f.submit(inflight)
    f.advance_to(1.2)
    assert not inflight[0].done
    lc.retire(9, now=1.2)
    assert lc.adapters[9].state == RETIRED
    assert 9 in f.cluster_of                       # not released: draining
    with pytest.raises(ValueError):                # but no longer routable
        lc.stamp(_reqs([9], rid0=5))
    f.run()
    assert inflight[0].done and inflight[0].adapter_epoch == 0
    assert 9 not in f.cluster_of                   # released after drain
    assert all(9 not in e.executor.raw_ids for e in f.engines)
    assert not f.engines[0].cache.is_resident(9)


# ---------------------------------------------------------------------------
# L5: retirement releases affinity, pages, and (lazily) the Sigma row
# ---------------------------------------------------------------------------


def test_retire_releases_affinity_and_bytes():  # L5
    """Invariant L5: retiring drops the routing home immediately, frees
    the adapter's cache bytes at drain, and drops the Sigma row at the
    next refresh (lazy shrink)."""
    cluster_of = {}
    f = _fleet(2, policy="cluster_affinity", cluster_of=cluster_of)
    lc = _lc(f, assign_fn=lambda aid: 900 + aid)   # private cluster each
    lc.register(100)
    reqs = _reqs([100])
    lc.stamp(reqs)
    f.submit(reqs)
    f.run()
    assert 1000 in f._home                         # cluster key homed
    lc.retire(100, now=5.0)
    assert 1000 not in f._home                     # affinity gone at once
    assert lc.stats.bytes_released > 0             # weights freed (drained)
    assert 100 in lc._shrink_pending
    lc.tick(10.0)
    lc.tick(10.05)
    assert lc.stats.n_shrunk == 1 and not lc._shrink_pending


def test_retire_keeps_shared_cluster_home():  # L5
    """The cluster affinity key survives a retire while another live
    adapter still maps to that cluster."""
    cluster_of = {}
    f = _fleet(2, policy="cluster_affinity", cluster_of=cluster_of)
    lc = _lc(f, assign_fn=lambda aid: 500)         # both share one cluster
    lc.register(100)
    lc.register(101)
    reqs = _reqs([100, 101])
    lc.stamp(reqs)
    f.submit(reqs)
    f.run()
    assert 500 in f._home
    lc.retire(100, now=5.0)
    assert 500 in f._home                          # 101 still lives there
    lc.retire(101, now=6.0)
    assert 500 not in f._home


# ---------------------------------------------------------------------------
# scoped rehome (membership-change regression)
# ---------------------------------------------------------------------------


class TestScopedRehome:
    def _homed_fleet(self):
        f = _fleet(2, policy="adapter_affinity")
        f.submit(_reqs([0, 1]))
        h0, h1 = f._home[0], f._home[1]
        assert h0 != h1                            # least-loaded spread
        return f, h0, h1

    def test_add_replica_keeps_existing_homes(self):
        """Regression: growing the fleet used to clear ALL affinity homes
        (a full re-home), churning every adapter's pinned-base locality;
        existing homes stay valid — only new load lands on the new
        replica."""
        f, h0, h1 = self._homed_fleet()
        f.add_replica(_engine())
        assert f._home[0] == h0 and f._home[1] == h1

    def test_retire_replica_drops_only_its_homes(self):
        f, h0, h1 = self._homed_fleet()
        f.retire_replica(h1)
        assert f._home[0] == h0                    # survivor untouched
        assert 1 not in f._home                    # retired replica's key

    def test_unscoped_rehome_clears_everything(self):
        f, _, _ = self._homed_fleet()
        f.rehome()
        assert f._home == {}


# ---------------------------------------------------------------------------
# grounded: incremental assignment / lazy shrink / refresh gate
# ---------------------------------------------------------------------------


def _two_family_bank(key, per=5, r_l=2, d=24, noise=0.02):
    k1, k2, k3, k4, kn = jax.random.split(key, 5)
    A1, B1 = (jax.random.normal(k1, (1, r_l, d)),
              jax.random.normal(k2, (1, d, r_l)))
    A2, B2 = (jax.random.normal(k3, (1, r_l, d)),
              jax.random.normal(k4, (1, d, r_l)))
    A = jnp.concatenate([jnp.tile(A1, (per, 1, 1)),
                         jnp.tile(A2, (per, 1, 1))])
    B = jnp.concatenate([jnp.tile(B1, (per, 1, 1)),
                         jnp.tile(B2, (per, 1, 1))])
    return A + noise * jax.random.normal(kn, A.shape), B


def test_assign_adapter_matches_full_assignment_scores():
    """The singleton fast path places a new adapter exactly where the
    full (n, k) assignment scan would."""
    A, B = _two_family_bank(jax.random.PRNGKey(0))
    c = cluster_jd(A, B, rank=4, n_clusters=2, jd_iters=20, outer_iters=5)
    for i in (0, 7):                               # one from each family
        j, sigma, rel = assign_adapter(A[i], B[i], c)
        full = _assignment_scores(A[i:i + 1], B[i:i + 1], c.U, c.V)[0]
        assert j == int(jnp.argmax(full))
        assert sigma.shape == (c.rank, c.rank)
        assert rel < 0.2                           # in-family: good fit


def test_add_and_drop_adapter_shapes_and_lazy_shrink():
    A, B = _two_family_bank(jax.random.PRNGKey(1))
    c = cluster_jd(A, B, rank=4, n_clusters=2, jd_iters=20)
    n = c.sigma.shape[0]
    c2, j, rel = add_adapter(c, A[0], B[0])        # re-add a family member
    assert c2.sigma.shape[0] == n + 1 and int(c2.assign[-1]) == j
    assert float(jnp.linalg.norm(c2.U - c.U)) == 0.0   # bases untouched
    c3 = drop_adapter(c2, n)                       # lazy shrink: row only
    assert c3.sigma.shape[0] == n
    assert bool(jnp.all(c3.sigma == c.sigma))


def test_refresh_gate_passes_in_family_and_rejects_regression():
    A, B = _two_family_bank(jax.random.PRNGKey(2))
    serving = cluster_jd(A, B, rank=4, n_clusters=2, jd_iters=20)
    # candidate absorbs one more in-family adapter, re-solved over n+1
    A1, B1 = (jnp.concatenate([A, A[:1]]), jnp.concatenate([B, B[:1]]))
    cand = cluster_jd(A1, B1, rank=4, n_clusters=2, jd_iters=20)
    g = refresh_gate(A1, B1, serving, cand, max_regression=0.05,
                     abs_slack=1e-3, max_new_rel_err=0.3)
    assert g["ok"] and g["new_worst_rel_err"] < 0.3
    # a garbage candidate (random bases) must be rejected
    kq = jax.random.PRNGKey(3)
    qU, _ = jnp.linalg.qr(jax.random.normal(kq, cand.U.shape))
    bad = cluster_jd(A1, B1, rank=4, n_clusters=2, jd_iters=0,
                     outer_iters=1, kmeans_iters=1)
    bad = type(bad)(U=qU, V=bad.V, sigma=bad.sigma * 0.0,
                    assign=bad.assign, diag=bad.diag)
    g_bad = refresh_gate(A1, B1, serving, bad, max_regression=0.05,
                         max_new_rel_err=0.3)
    assert not g_bad["ok"]


# ---------------------------------------------------------------------------
# churn workload + study driver + autoscaler signal
# ---------------------------------------------------------------------------


def test_churn_workload_respects_lifetimes():
    spec = ChurnSpec(base=WorkloadSpec(n_requests=200, n_adapters=16,
                                       arrival="poisson", arrival_rate=80.0,
                                       seed=0),
                     churn_rate=3.0, lifetime=0.8, request_rate=25.0, seed=1)
    reqs, events = make_churn_workload(spec)
    assert events == sorted(events, key=lambda e: e.t)
    windows = {}
    for e in events:
        if e.action == "register":
            windows[e.adapter_id] = [e.t, None]
        elif e.action == "retire":
            windows[e.adapter_id][1] = e.t
    for r in reqs:
        if r.adapter_id >= 16:                     # churn adapter
            lo, hi = windows[r.adapter_id]
            assert lo <= r.arrival_time < hi
    reqs2, events2 = make_churn_workload(spec)     # deterministic
    assert [r.arrival_time for r in reqs2] == [r.arrival_time for r in reqs]
    assert [(e.t, e.action, e.adapter_id) for e in events2] \
        == [(e.t, e.action, e.adapter_id) for e in events]


def test_churn_study_end_to_end_cost_model():
    """Full cost-model fleet under churn: every request (base + churn)
    completes, lifecycle counters line up with the event stream, and no
    rollout ever fails into production (default gate)."""
    cfg = get_config("mistral-7b")
    n = 16
    setting, cluster_of, budget = memory_matched_setup(cfg, n)
    # memory matching covers bases + sigmas only; hot-registered adapters
    # serve RAW until a refresh lands, so the cell needs LoRA headroom
    fp_lora = serving_footprint(cfg, "lora", n, setting)
    budget += 4 * fp_lora.lora_bytes_per_adapter
    fleet = build_fleet(cfg, "jd", n, budget,
                        FleetConfig(n_replicas=2, policy="cluster_affinity",
                                    spill_requests=1e9),
                        ServingHardware(), cluster_of, setting)
    lc = AdapterLifecycle(fleet, LifecycleConfig(refresh_interval=0.5),
                          assign_fn=lambda aid: aid % setting["clusters"])
    spec = ChurnSpec(base=WorkloadSpec(n_requests=150, n_adapters=n,
                                       arrival="poisson", arrival_rate=100.0,
                                       popularity="zipf", seed=2),
                     churn_rate=2.0, lifetime=0.8, request_rate=20.0, seed=3)
    reqs, events = make_churn_workload(spec)
    stats = run_churn_study(fleet, lc, reqs, events, window=0.25)
    assert stats.total.n_requests == len(reqs)
    assert all(r.done for r in reqs)
    d = stats.lifecycle
    n_reg = sum(1 for e in events if e.action == "register")
    n_ret = sum(1 for e in events if e.action == "retire")
    assert d["n_registered"] == n_reg and d["n_retired"] == n_ret
    assert d["n_rollbacks"] == 0 and d["n_gate_failures"] == 0
    assert d["raw_requests"] + d["assigned_requests"] \
        == sum(1 for r in reqs if r.adapter_id >= n)
    assert "lifecycle" in stats.to_dict()


def test_autoscaler_refresh_veto_blocks_scale_down():
    """A comfortable window normally classifies decode cold (-1 replica);
    with a basis rollout in flight the lifecycle signal vetoes the
    shrink — replicas take turns stalled on base swaps."""
    scaler = JointAutoscaler(
        JointAutoscalerConfig(cooldown_intervals=0),
        SLOConfig(ttft_p95=0.5),
        HardwareBudget(BudgetConfig(total_accelerators=8)))
    comfortable = dict(ttfts=[0.01] * 8, tpots=[0.001] * 8,
                       decode_waits=[0.01] * 8, prefill_lags=[0.01] * 8,
                       prefill_backlog=0, decode_backlog=0)
    assert scaler.decide(1.0, n_prefill=1, n_decode=2,
                         refresh_active=True, **comfortable) == (0, 0)
    assert scaler.history[-1].refresh_active
    assert scaler.decide(2.0, n_prefill=1, n_decode=2,
                         refresh_active=False, **comfortable) == (0, -1)
