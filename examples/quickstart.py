"""Quickstart: compress a LoRA collection with joint diagonalization.

    PYTHONPATH=src python examples/quickstart.py

Builds a synthetic collection of 32 adapters, compresses it three ways
(JD-Full, JD-Diag, clustered), prints reconstruction quality and parameter
savings, and validates the §6.5 hyperparameter recommendation procedure.
"""
import jax
import jax.numpy as jnp

from repro.core import (CompressionConfig, LoRABank, compress_bank,
                        parameter_counts, recommend)

# --- a collection of 32 rank-8 adapters for a d=512 module ---------------
key = jax.random.PRNGKey(0)
n, r_l, d = 32, 8, 512
sh_a = jax.random.normal(key, (r_l, d))            # trained LoRAs share
sh_b = jax.random.normal(jax.random.PRNGKey(1), (d, r_l))  # structure
A = sh_a[None] + 0.3 * jax.random.normal(key, (n, r_l, d))
B = sh_b[None] + 0.3 * jax.random.normal(jax.random.PRNGKey(2), (n, d, r_l))
bank = LoRABank(A=A, B=B, ranks=jnp.full((n,), r_l, jnp.int32))

for method, rank, k in [("jd_full", 16, 1), ("jd_diag", 32, 1),
                        ("jd_full_eig", 16, 4)]:
    cm = compress_bank(bank, CompressionConfig(method=method, rank=rank,
                                               n_clusters=k, iters=15))
    pc = parameter_counts(d, d, n, rank, k, lora_rank=r_l)
    print(f"{method:12s} rank={rank:3d} clusters={k}  "
          f"recon_loss={cm.metrics['loss']:.4f}  "
          f"params_saved={pc['saved_ratio']:.1%}")

rec = recommend({"layer.q": bank}, rank=16, max_clusters=8)
print(f"\n§6.5 recommendation: rank={rec.rank}, clusters={rec.n_clusters}, "
      f"probe losses={ {k: round(v, 3) for k, v in rec.probe_losses.items()} }")
