"""Drive the multi-pod dry-run from Python (deliverable (e) entry point).

    PYTHONPATH=src python examples/multi_pod_dryrun.py --arch qwen3-1.7b

Compiles train/prefill/decode steps for the production meshes (16x16 and
2x16x16 = 512 chips) and prints the roofline terms.
"""
import argparse
import json
import subprocess
import sys

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--shape", default="train_4k")
    args = ap.parse_args()
    for flag in ([], ["--multi-pod"]):
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", args.arch, "--shape", args.shape, "--force",
               "--out", "/tmp/dryrun_example"] + flag
        subprocess.run(cmd, check=True)
    name = f"{args.arch}__{args.shape}__pod2x16x16.json"
    rec = json.load(open(f"/tmp/dryrun_example/{name}"))
    print(json.dumps(rec["roofline"], indent=2))
