"""Train a ~100M-parameter decoder LM for a few hundred steps on CPU with the
full production substrate: AdamW + cosine schedule, microbatching, async
checkpointing, fault-tolerant runner.

    PYTHONPATH=src python examples/train_100m.py [--steps 300]
"""
import argparse

from repro.configs.base import LoRAConfig, ModelConfig
from repro.launch.train import train_full


def config_100m() -> ModelConfig:
    # ~104M params: 12L, d=768, 12H, d_ff=2048, vocab 32000 (tied embeddings)
    return ModelConfig(
        name="repro-100m", family="dense", num_layers=12, d_model=768,
        num_heads=12, num_kv_heads=4, head_dim=64, d_ff=2048,
        vocab_size=32000, tie_embeddings=True,
        lora=LoRAConfig(rank=16), attn_chunk_q=0, scan_layers=True)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default="/tmp/repro_100m")
    args = ap.parse_args()
    cfg = config_100m()
    print(f"params: {cfg.param_count()/1e6:.1f}M")
    train_full(cfg, args.steps, args.batch, args.seq, args.ckpt,
               ckpt_every=50, log_every=10)
