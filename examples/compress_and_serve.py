"""End-to-end driver: serve a small model with batched multi-LoRA requests.

    PYTHONPATH=src python examples/compress_and_serve.py

1. Build a reduced mistral-7b-family model (real weights, CPU).
2. Create 8 adapters; serve 24 asynchronous requests through the
   continuous-batching engine with REAL prefill/decode (uncompressed mode).
3. Serve the same workload with the JD-compressed collection.
4. Run the paper-scale (Fig. 1) throughput study with the v5e cost model.
"""

from repro.configs import get_config, smoke_config
from repro.launch.serve import run_real
from repro.serving.simulator import WorkloadConfig, run_throughput_study

cfg = smoke_config("mistral-7b")

print("== real execution (reduced model, CPU) ==")
for mode in ("lora", "jd"):
    stats = run_real(cfg, n_adapters=8, n_requests=24, mode=mode,
                     max_batch=8)
    print(f"mode={mode:5s} rps={stats['throughput_rps']:.2f} "
          f"tps={stats['throughput_tps']:.2f} "
          f"mean_latency={stats['mean_latency_s']:.2f}s")

print("\n== paper-scale cost-model study (Fig. 1), mistral-7b on v5e ==")
rows = run_throughput_study(get_config("mistral-7b"), [4, 64, 1024],
                            WorkloadConfig(n_requests=300, new_tokens=10))
for r in rows:
    print(f"N={r['n_adapters']:5d}  jd={r['jd']['throughput_rps']:.1f} rps  "
          f"uncompressed={r['lora']['throughput_rps']:.1f} rps  "
          f"ratio={r['throughput_ratio_jd_vs_lora']:.2f}  "
          f"(jd keeps {r['jd_frac_of_single']:.0%} of single-LoRA)")
