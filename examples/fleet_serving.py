"""Fleet serving quickstart: skewed traffic across replicas with routing.

Builds a 4-replica fleet of cost-model engines for both serving modes,
drives it with a Zipf-skewed Poisson workload, and prints fleet-level
throughput + tail-latency (TTFT / TPOT) for two routing policies.  Also
shows CSV trace replay round-tripping through the same path.

Run:  PYTHONPATH=src python examples/fleet_serving.py
"""
from __future__ import annotations

import os
import tempfile

from repro.configs import get_config
from repro.serving.engine import ServingHardware
from repro.serving.router import FleetConfig
from repro.serving.simulator import build_fleet, memory_matched_setup
from repro.serving.workload import (WorkloadSpec, load_trace, make_workload,
                                    save_trace)


def main():
    cfg = get_config("mistral-7b")
    n_adapters = 256
    setting, cluster_of, budget = memory_matched_setup(cfg, n_adapters)

    wl = WorkloadSpec(n_requests=500, n_adapters=n_adapters, new_tokens=10,
                      popularity="zipf", zipf_alpha=1.0,
                      arrival="gamma", arrival_rate=2000.0, burst_cv=4.0)
    requests = make_workload(wl)
    print(f"workload: {len(requests)} requests, Zipf(1.0) over "
          f"{n_adapters} adapters, bursty arrivals\n")

    with tempfile.TemporaryDirectory() as tmp:
        # trace replay round-trip: the same stream can come from a CSV
        trace = os.path.join(tmp, "trace.csv")
        save_trace(trace, requests)
        for mode in ("lora", "jd"):
            for policy in ("round_robin", "cluster_affinity"):
                fleet = build_fleet(cfg, mode, n_adapters, budget,
                                    FleetConfig(n_replicas=4, policy=policy),
                                    ServingHardware(), cluster_of, setting)
                fleet.submit(load_trace(trace))
                d = fleet.run().to_dict()
                print(f"{mode:5s} {policy:18s} "
                      f"rps={d['throughput_rps']:7.2f}  "
                      f"p99={d['latency_p99_s'] * 1e3:7.1f}ms  "
                      f"ttft_p95={d['ttft_p95_s'] * 1e3:6.1f}ms  "
                      f"tpot_p50={d['tpot_p50_s'] * 1e3:5.1f}ms  "
                      f"swaps={d['n_swaps']}")


if __name__ == "__main__":
    main()
