"""Online adapter lifecycle walkthrough: register -> update -> retire.

Builds a 3-replica compressed (jd-mode) fleet over the paper's
128-adapter setting, then exercises the control plane
(repro.serving.lifecycle) live: hot-register a new tenant mid-run and
serve it raw immediately, let the background basis refresh absorb it
into a cluster behind the quality gate, ship a weight update under an
epoch bump while its old requests drain, and finally retire it.  Prints
the adapter's state transitions and the lifecycle counters.

The state machine and invariants (L1-L5) are specified in
docs/lifecycle.md; the churn benchmark built on the same pieces is
benchmarks/adapter_churn.py.

Run:  PYTHONPATH=src python examples/adapter_lifecycle.py
"""
from __future__ import annotations

from repro.configs import get_config
from repro.serving.engine import ServingHardware
from repro.serving.lifecycle import (AdapterLifecycle, LifecycleConfig,
                                     weight_key)
from repro.serving.router import FleetConfig
from repro.serving.simulator import (build_fleet, memory_matched_setup,
                                     serving_footprint)
from repro.serving.workload import WorkloadSpec, make_workload


def show(lc, aid, label):
    st = lc.adapters[aid]
    print(f"  [{label:22s}] adapter {aid}: state={st.state:16s} "
          f"epoch={st.epoch} cluster={st.cluster} "
          f"basis_version={lc.basis_version}")


def main():
    cfg = get_config("mistral-7b")
    n = 128
    setting, cluster_of, budget = memory_matched_setup(cfg, n)
    # Appendix-F matching covers bases + Sigmas; raw-serving churn needs
    # explicit LoRA headroom on top
    budget += 4 * serving_footprint(cfg, "lora", n,
                                    setting).lora_bytes_per_adapter
    fleet = build_fleet(cfg, "jd", n, budget,
                        FleetConfig(n_replicas=3, policy="cluster_affinity"),
                        ServingHardware(), cluster_of, setting)
    lc = AdapterLifecycle(
        fleet, LifecycleConfig(refresh_interval=1.0),
        assign_fn=lambda aid: aid % setting["clusters"])

    base = make_workload(WorkloadSpec(
        n_requests=200, n_adapters=n, popularity="zipf", zipf_alpha=1.0,
        arrival="poisson", arrival_rate=80.0, new_tokens=10))
    print(f"base load: {len(base)} requests over the offline collection\n")

    # -- hot register: servable immediately, raw -------------------------
    tenant = 1000
    lc.register(tenant, now=0.0)
    show(lc, tenant, "register")
    burst = [r for r in base[:40]]
    mine = make_workload(WorkloadSpec(n_requests=8, n_adapters=1,
                                      arrival="poisson", arrival_rate=40.0,
                                      new_tokens=10, seed=7))
    for r in mine:
        r.rid, r.adapter_id = 10_000 + r.rid, tenant
    lc.stamp(burst + mine)
    fleet.submit(burst + mine)
    fleet.advance_to(0.5)
    done = [r for r in mine if r.done]
    print(f"  first tenant requests done by t=0.5s: {len(done)}/8, "
          f"ttft={mine[0].ttft * 1e3:.1f}ms (raw SGMV path, invariant L1)")

    # -- background refresh absorbs it ------------------------------------
    lc.tick(1.0)                  # cadence elapsed: rollout walks replicas
    fleet.advance_to(1.2)
    lc.tick(1.2)
    show(lc, tenant, "after refresh")
    print(f"  gate checks={lc.stats.n_gate_checks} "
          f"rollbacks={lc.stats.n_rollbacks} (invariants L2/L3)")

    # -- weight update: epoch bump, in-flight drains on old epoch ---------
    upd = [r for r in base[40:80]]
    lc.stamp(upd)
    fleet.submit(upd)
    lc.update(tenant, now=1.3)
    show(lc, tenant, "update (epoch bump)")
    req = mine[0].__class__(rid=20_000, adapter_id=tenant, prompt_len=128,
                            max_new_tokens=10, arrival_time=1.35)
    lc.stamp([req])
    fleet.submit([req])
    print(f"  new request decodes against weight key {weight_key(req)} "
          f"(invariant L4)")

    # -- retire: drain, release, lazy shrink ------------------------------
    fleet.advance_to(2.0)
    lc.retire(tenant, now=2.0)
    show(lc, tenant, "retire")
    rest = [r for r in base[80:]]
    lc.stamp(rest)
    fleet.submit(rest)
    stats = fleet.run()
    lc.tick(3.0 + stats.total.wall_time)   # next cadence: Sigma row drops
    lc.tick(3.1 + stats.total.wall_time)
    show(lc, tenant, "after drain+shrink")

    print(f"\nfleet: rps={stats.total.throughput_rps:.1f} "
          f"ttft_p95={stats.total.ttft_pct(95) * 1e3:.1f}ms")
    print("lifecycle:", lc.stats.to_dict())


if __name__ == "__main__":
    main()
